"""Extraction algorithms vs the brute-force oracle (filter, index, ssjoin)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.eejoin import EEJoinConfig, EEJoinOperator
from repro.core.filter import build_ish_filter, measure_fp_rate
from repro.core.signatures import LshParams, entity_signatures
from repro.extraction import engine as E
from repro.extraction.oracle import oracle_extract

GAMMA = 0.8


@pytest.fixture(scope="module")
def setup(small_corpus):
    c = small_corpus
    d = c.dictionary
    flt = build_ish_filter(d, GAMMA)
    return dict(
        c=c,
        d=d,
        docs=jnp.asarray(c.doc_tokens),
        ddict=E.DeviceDictionary.from_host(d),
        flt=(jnp.asarray(flt.bits), flt.num_bits, flt.num_hashes),
        flt_host=flt,
        truth_extra=oracle_extract(c.doc_tokens, d, GAMMA, "extra"),
        truth_var=oracle_extract(c.doc_tokens, d, GAMMA, "variant_exact"),
    )


def _cands(s, params):
    base, surv = E.survival_mask(s["docs"], s["d"].max_len, s["flt"])
    return E.compact_candidates(base, surv, params.max_candidates)


def test_filter_never_drops_true_mentions(setup):
    s = setup
    base, surv = E.survival_mask(s["docs"], s["d"].max_len, s["flt"])
    surv = np.asarray(surv)
    for (doc, pos, length, _e) in s["truth_extra"]:
        assert surv[doc, pos, length - 1], "ISH filter dropped a true mention"


def test_filter_prunes_substantially(setup):
    s = setup
    base, surv_nf = E.survival_mask(s["docs"], s["d"].max_len, None)
    _, surv = E.survival_mask(s["docs"], s["d"].max_len, s["flt"])
    kept = float(np.asarray(surv).sum()) / float(np.asarray(surv_nf).sum())
    assert kept < 0.6, f"filter kept {kept:.0%} of candidates"


def test_filter_fp_rate_bounded(setup):
    rng = np.random.default_rng(0)
    sample = rng.integers(1, setup["d"].vocab_size, size=10000).astype(np.int32)
    assert measure_fp_rate(setup["flt_host"], sample) < 0.05


@pytest.mark.parametrize("kind,truth_key", [
    ("word", "truth_extra"),
    ("prefix", "truth_extra"),
    ("variant", "truth_var"),
])
def test_index_paths_match_oracle(setup, kind, truth_key):
    s = setup
    params = E.ExtractParams(
        gamma=GAMMA, scheme=kind, max_candidates=8192, result_capacity=8192
    )
    cands = _cands(s, params)
    parts = E.build_index_partitions(s["d"], kind, GAMMA, memory_budget_bytes=1 << 30)
    assert len(parts) == 1
    got = E.extract_index_part(cands, parts[0], s["ddict"], params).to_set()
    assert got == s[truth_key]


@pytest.mark.parametrize("kind", ["word", "prefix", "variant"])
def test_index_multipass_equals_single_pass(setup, kind):
    """Def. 3's |E|/M_e multi-pass must not change results."""
    s = setup
    params = E.ExtractParams(
        gamma=GAMMA, scheme=kind, max_candidates=8192, result_capacity=8192
    )
    cands = _cands(s, params)
    big = E.build_index_partitions(s["d"], kind, GAMMA, memory_budget_bytes=1 << 30)
    small = E.build_index_partitions(s["d"], kind, GAMMA, memory_budget_bytes=1200)
    assert len(small) > 1, "budget should force multiple passes"
    got_big = E.extract_index_part(cands, big[0], s["ddict"], params).to_set()
    got_small = set()
    for part in small:
        got_small |= E.extract_index_part(cands, part, s["ddict"], params).to_set()
    assert got_small == got_big


@pytest.mark.parametrize("scheme,truth_key", [
    ("word", "truth_extra"),
    ("prefix", "truth_extra"),
    ("variant", "truth_var"),
])
def test_ssjoin_paths_match_oracle(setup, scheme, truth_key):
    s = setup
    params = E.ExtractParams(
        gamma=GAMMA, scheme=scheme, max_candidates=8192, result_capacity=16384
    )
    cands = _cands(s, params)
    table = E.build_sig_table(entity_signatures(scheme, s["d"], GAMMA))
    got = E.extract_ssjoin_local(cands, table, s["ddict"], params).to_set()
    assert got == s[truth_key]


def test_ssjoin_lsh_high_recall_no_false_positives(setup):
    s = setup
    lsh = LshParams(bands=16, rows=1)  # aggressive banding -> high recall
    params = E.ExtractParams(
        gamma=GAMMA, scheme="lsh", max_candidates=8192,
        result_capacity=16384, lsh=lsh,
    )
    cands = _cands(s, params)
    table = E.build_sig_table(entity_signatures("lsh", s["d"], GAMMA, lsh))
    got = E.extract_ssjoin_local(cands, table, s["ddict"], params).to_set()
    assert got <= s["truth_extra"], "verification must kill false positives"
    recall = len(got & s["truth_extra"]) / len(s["truth_extra"])
    assert recall > 0.9, f"LSH recall {recall:.0%}"


def test_overflow_is_surfaced(setup):
    s = setup
    params = E.ExtractParams(
        gamma=GAMMA, scheme="word", max_candidates=64, result_capacity=64
    )
    cands = _cands(s, params)
    assert int(cands["overflow"]) > 0
    assert int(cands["n_survive"]) > 64


def test_eejoin_operator_end_to_end(zipf_corpus):
    c = zipf_corpus
    op = EEJoinOperator(c.dictionary, EEJoinConfig(gamma=GAMMA))
    stats = op.gather_statistics(c.doc_tokens[:8], total_docs=c.doc_tokens.shape[0])
    from repro.core.cost_model import CostParams

    plan = op.choose_plan(stats, CostParams(num_devices=4))
    prepared = op.prepare(plan, CostParams(num_devices=4))
    m = op.execute(prepared, jnp.asarray(c.doc_tokens))
    got = m.to_set()

    # per-side oracle: schemes define each side's exact predicate
    truth = set()
    for side in prepared.sides:
        a = side.ddict.entity_offset
        b = a + side.ddict.tokens.shape[0]
        sim = "variant_exact" if side.side.scheme == "variant" else "extra"
        tr = oracle_extract(c.doc_tokens, c.dictionary, GAMMA, sim)
        truth |= {t for t in tr if a <= t[3] < b}
        if side.side.scheme == "lsh":
            pytest.skip("probabilistic side chosen; covered elsewhere")
    assert got == truth

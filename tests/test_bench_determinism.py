"""Seed-determinism regression for the serving bench.

The bench's claim (bench_serving module docstring): arrivals,
admission, and batch composition run on a virtual clock and are
deterministic run-to-run for a given seed — only measured stage wall
times vary. Regressing this silently (e.g. a real-time read sneaking
into the flush path) would make bench rows incomparable across runs,
so this pins it: two executions of the same load level must agree
byte-for-byte on the deterministic summary JSON, and the request
stream itself must be reproducible from its seed.
"""
import json

import numpy as np

from benchmarks.bench_serving import (
    SEED,
    _request_stream,
    _run_level,
    deterministic_summary,
)
from repro.core.eejoin import EEJoinConfig
from repro.data.synth import make_corpus
from repro.serving import SessionCache
from repro.serving.session import pure_plan


def _setup():
    corpus = make_corpus(num_docs=16, doc_len=96, vocab_size=2048,
                         num_entities=32, seed=SEED)
    cfg = EEJoinConfig(gamma=0.8, max_candidates=8192,
                       result_capacity=16384, use_kernel=True)
    cache = SessionCache()
    sess = cache.get_or_create(corpus.dictionary, cfg,
                               plan=pure_plan("prefix"))
    return corpus, cache, sess


def test_request_stream_reproducible_from_seed():
    corpus, _, _ = _setup()
    s1 = _request_stream(corpus, 16, 120.0, SEED + 1)
    s2 = _request_stream(corpus, 16, 120.0, SEED + 1)
    assert [(a, i) for a, i, _ in s1] == [(a, i) for a, i, _ in s2]
    assert all(np.array_equal(d1, d2)
               for (_, _, d1), (_, _, d2) in zip(s1, s2))


def test_bench_level_deterministic_summary_identical():
    corpus, cache, sess = _setup()
    stream = _request_stream(corpus, 16, 120.0, SEED + 1)

    def run():
        # fresh service per run, same session cache (as the bench's
        # warmup + levels share one) — composition must not depend on
        # accumulated serving state like lane hints
        svc, records = _run_level(cache, sess, stream, batch_docs=8,
                                  max_delay_s=0.02)
        return deterministic_summary(svc, records), svc.results_set()

    (d1, m1), (d2, m2) = run(), run()
    assert json.dumps(d1, sort_keys=True) == json.dumps(d2, sort_keys=True)
    assert m1 == m2  # served match sets identical, not just counts
    assert d1["completed"] == 16 and d1["rejected"] == 0

"""Property-based statement of the continuous-calibration contracts.

Hypothesis drives the three replan-loop invariants the drift tests
assume (skipped cleanly when hypothesis is not installed):

* ``refit_params`` is idempotent on a stationary stream — once the
  constants match the measurements, refitting against the same
  measurements is the identity (the scale factors are degree-1
  homogeneous, so the second fit's factors are exactly 1);
* the plan ``replan_choice`` returns never models costlier than the
  stale plan under the same refitted params (the search is floored by
  the stale plan re-costed);
* the ``Ewma`` estimator is invariant to batch-boundary placement — a
  segment of n units at one rate folds identically whether it arrives
  whole or split at any point.
"""
import dataclasses
import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.calibrate import refit_params
from repro.core.cost_model import OBJ_JOB, OBJ_WORK, CostParams
from repro.core.eejoin import EEJoinConfig, EEJoinOperator
from repro.core.plan import PlanSide
from repro.core.search import plan_cost
from repro.data.synth import make_corpus
from repro.serving.replan import Ewma, replan_choice
from repro.serving.session import pure_plan

OPTIONS = (("index", "prefix"), ("ssjoin", "word"),
           ("ssjoin", "prefix"), ("ssjoin", "lsh"))

_corpus = make_corpus(num_docs=16, doc_len=48, vocab_size=256,
                      num_entities=16, max_entity_len=4, seed=7)
_op = EEJoinOperator(
    _corpus.dictionary,
    EEJoinConfig(max_candidates=2048, result_capacity=4096,
                 options=OPTIONS),
)
_stats = _op.gather_statistics(_corpus.doc_tokens,
                               total_docs=_corpus.doc_tokens.shape[0])
E = _corpus.dictionary.num_entities


class _Obs:
    """Duck-typed stand-in for ObservedStats (what refit_params reads)."""

    def __init__(self, density, probe, verify):
        self.density = density
        self.probe_s_per_window = probe
        self.verify_s_per_survivor = verify


def _params_close(a: CostParams, b: CostParams, rel=1e-9) -> bool:
    for f in dataclasses.fields(CostParams):
        x, y = getattr(a, f.name), getattr(b, f.name)
        if isinstance(x, dict):
            if set(x) != set(y) or any(
                not math.isclose(x[k], y[k], rel_tol=rel) for k in x
            ):
                return False
        elif isinstance(x, float):
            if not math.isclose(x, y, rel_tol=rel, abs_tol=1e-300):
                return False
        elif x != y:
            return False
    return True


_rate = st.floats(1e-12, 1e-3, allow_nan=False, allow_infinity=False)
_density = st.floats(1e-6, 0.9, allow_nan=False, allow_infinity=False)


@settings(max_examples=50, deadline=None)
@given(density=_density, probe=_rate, verify=_rate,
       schemes=st.lists(st.sampled_from(("word", "prefix", "lsh")),
                        min_size=1, max_size=2, unique=True))
def test_refit_idempotent_on_stationary_stream(density, probe, verify,
                                               schemes):
    obs = _Obs(density, probe, verify)
    p1 = refit_params(CostParams(num_devices=1), obs,
                      schemes=tuple(schemes))
    p2 = refit_params(p1, obs, schemes=tuple(schemes))
    assert _params_close(p1, p2)


def test_refit_cold_observed_is_identity():
    base = CostParams(num_devices=1)
    nan = float("nan")
    assert _params_close(refit_params(base, _Obs(nan, nan, nan)), base)


_side = st.sampled_from([PlanSide(a, s) for a, s in OPTIONS])


@settings(max_examples=40, deadline=None)
@given(split=st.integers(0, E), head=_side, tail=_side,
       objective=st.sampled_from((OBJ_WORK, OBJ_JOB)),
       density=_density, probe=_rate, verify=_rate)
def test_replanned_cost_never_exceeds_stale(split, head, tail, objective,
                                            density, probe, verify):
    params = refit_params(CostParams(num_devices=1),
                          _Obs(density, probe, verify))
    stale = dataclasses.replace(pure_plan("prefix"), split=split,
                                head=head, tail=tail, objective=objective)
    choice, stale_cost = replan_choice(_stats, params, stale, objective,
                                       OPTIONS)
    assert stale_cost == pytest.approx(
        plan_cost(_stats, params, stale, objective))
    assert choice.predicted_cost <= stale_cost * (1 + 1e-9)


_weight = st.floats(1e-3, 1e5, allow_nan=False, allow_infinity=False)
_x = st.floats(-1e6, 1e6, allow_nan=False, allow_infinity=False)


@settings(max_examples=100, deadline=None)
@given(history=st.lists(st.tuples(_x, _weight), min_size=0, max_size=5),
       x=_x, w=_weight, cut=st.floats(1e-6, 1 - 1e-6),
       halflife=st.floats(1.0, 1e5))
def test_ewma_invariant_to_batch_boundaries(history, x, w, cut, halflife):
    """Folding (x, w) whole == folding (x, w*cut) then (x, w*(1-cut)),
    from any prior state."""
    whole, split = Ewma(halflife), Ewma(halflife)
    for hx, hw in history:
        whole.update(hx, hw)
        split.update(hx, hw)
    whole.update(x, w)
    split.update(x, w * cut)
    split.update(x, w * (1.0 - cut))
    if math.isnan(whole.value):
        assert math.isnan(split.value)
    else:
        assert math.isclose(whole.value, split.value,
                            rel_tol=1e-6, abs_tol=1e-9)

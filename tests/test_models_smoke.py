"""Per-architecture smoke tests: reduced config, one forward + one decode
step on CPU; asserts shapes and finiteness (deliverable f)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_smoke_config
from repro.models.model import build_model, lm_loss
from repro.models.sharding import ShardingRules
from repro.compat import set_mesh

B, S = 2, 64


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _context(cfg, batch):
    if cfg.context_len:
        rng = np.random.default_rng(0)
        return jnp.asarray(
            rng.normal(size=(batch, cfg.context_len, cfg.context_dim)).astype(np.float32)
        ).astype(jnp.bfloat16)
    return None


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_loss(arch):
    cfg = get_smoke_config(arch)
    mesh = _mesh()
    model = build_model(cfg, ShardingRules(mesh))
    params, specs = model.init(jax.random.PRNGKey(0))
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
    )
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 1, cfg.vocab_size)
    ctx = _context(cfg, B)
    with set_mesh(mesh):
        logits, aux = jax.jit(model.forward)(params, tokens, ctx)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), f"{arch}: NaN/inf logits"
    labels = jnp.roll(tokens, -1, axis=1)
    loss, parts = lm_loss(cfg, logits, labels, moe_aux=aux["moe_aux"])
    assert bool(jnp.isfinite(loss)), f"{arch}: NaN loss"
    assert float(parts["nll"]) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_decreases_loss(arch):
    """One SGD step on a repeated batch should reduce the loss."""
    cfg = get_smoke_config(arch)
    mesh = _mesh()
    model = build_model(cfg, ShardingRules(mesh))
    params, _ = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 1, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)
    ctx = _context(cfg, B)

    def loss_fn(p):
        logits, aux = model.forward(p, tokens, ctx)
        return lm_loss(cfg, logits, labels, moe_aux=aux["moe_aux"])[0]

    with set_mesh(mesh):
        l0, g = jax.jit(jax.value_and_grad(loss_fn))(params)
        gnorm = jax.tree.reduce(
            lambda a, b: a + b, jax.tree.map(lambda x: jnp.abs(x.astype(jnp.float32)).sum(), g)
        )
        assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0, f"{arch}: bad grads"
        lr = 3e-3
        p1 = jax.tree.map(lambda p, gg: (p - lr * gg.astype(p.dtype)).astype(p.dtype), params, g)
        l1 = jax.jit(loss_fn)(p1)
    assert float(l1) < float(l0), f"{arch}: loss did not decrease ({l0} -> {l1})"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    """Token-by-token decode logits == teacher-forced forward logits."""
    cfg = get_smoke_config(arch)
    n = 8
    mesh = _mesh()
    model = build_model(cfg, ShardingRules(mesh))
    params, _ = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, n), 1, cfg.vocab_size)
    ctx = _context(cfg, B)
    with set_mesh(mesh):
        full_logits, _ = jax.jit(model.forward)(params, tokens, ctx)
        cache = model.init_cache(params, B, max_len=32, kv_splits=2, context=ctx)
        step = jax.jit(model.decode_step)
        decode_logits = []
        for t in range(n):
            lg, cache = step(params, cache, tokens[:, t], ctx)
            decode_logits.append(lg)
    dec = jnp.stack(decode_logits, axis=1).astype(jnp.float32)
    ref = full_logits.astype(jnp.float32)
    # bf16 accumulation noise through deep stacks: absolute tolerance
    # (logits are O(1) at init; relative error is meaningless near 0)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(ref), rtol=0.0, atol=0.15,
        err_msg=f"{arch}: incremental decode diverges from forward",
    )
    # argmax agreement is the semantically meaningful check at bf16
    # (tiny random smoke models have near-tied logits -> 0.9 bar)
    agree = (dec.argmax(-1) == ref.argmax(-1)).mean()
    assert float(agree) >= 0.9, f"{arch}: decode argmax agreement {agree}"

"""Flash-attention custom VJP vs the naive chunked reference: outputs
AND gradients must match (same math, different memory schedule)."""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.flash import flash_attention
from repro.models.layers import chunked_attention


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


CASES = [
    # (B, Sq, Skv, H, KH, D, causal, window, q_chunk, kv_chunk)
    (2, 64, 64, 4, 4, 16, True, 0, 16, 16),
    (2, 64, 64, 8, 2, 16, True, 0, 32, 16),   # GQA
    (1, 48, 48, 4, 4, 8, True, 24, 16, 16),   # sliding window
    (2, 32, 80, 4, 4, 16, False, 0, 16, 32),  # cross-attn, ragged KV (pad)
    (1, 60, 37, 2, 2, 8, False, 0, 16, 16),   # prime KV length (pad path)
]


@pytest.mark.parametrize("case", CASES)
def test_flash_matches_naive_forward_and_grads(case):
    B, Sq, Skv, H, KH, D, causal, window, qc, kc = case
    rng = np.random.default_rng(hash(case) % 2**31)
    q = _rand(rng, B, Sq, H, D)
    k = _rand(rng, B, Skv, KH, D)
    v = _rand(rng, B, Skv, KH, D)
    qpos = jnp.arange(Sq)
    kpos = jnp.arange(Skv)

    def run(fn, q, k, v):
        o = fn(q, k, v, q_positions=qpos, kv_positions=kpos,
               causal=causal, window=window, q_chunk=qc, kv_chunk=kc)
        return (o * jnp.asarray(
            rng.standard_normal(o.shape), o.dtype)).sum()

    # fix the cotangent seed across both calls
    rng = np.random.default_rng(0)
    l_naive, g_naive = jax.value_and_grad(
        lambda *a: run(chunked_attention, *a), argnums=(0, 1, 2)
    )(q, k, v)
    rng = np.random.default_rng(0)
    l_flash, g_flash = jax.value_and_grad(
        lambda *a: run(flash_attention, *a), argnums=(0, 1, 2)
    )(q, k, v)

    assert np.allclose(l_naive, l_flash, rtol=1e-4, atol=1e-4)
    for a, b, name in zip(g_naive, g_flash, "qkv"):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-3,
            err_msg=f"grad d{name} mismatch",
        )


def test_flash_fully_masked_rows_are_zero():
    """Window smaller than the gap: some rows see no keys at all."""
    B, S, H, D = 1, 16, 2, 8
    rng = np.random.default_rng(3)
    q = _rand(rng, B, S, H, D)
    k = _rand(rng, B, S, H, D)
    v = _rand(rng, B, S, H, D)
    # kv positions far in the past => window excludes everything
    kpos = jnp.arange(S) - 10_000
    out = flash_attention(q, k, v, q_positions=jnp.arange(S),
                          kv_positions=kpos, causal=True, window=4,
                          q_chunk=8, kv_chunk=8)
    assert np.allclose(np.asarray(out), 0.0)
    g = jax.grad(lambda q: flash_attention(
        q, k, v, q_positions=jnp.arange(S), kv_positions=kpos,
        causal=True, window=4, q_chunk=8, kv_chunk=8).sum())(q)
    assert np.isfinite(np.asarray(g)).all()


def test_flash_in_model_forward_matches_naive_model():
    """Whole-model check: cfg.use_flash flips only the attention path."""
    import dataclasses

    from repro.configs.registry import get_smoke_config
    from repro.launch.mesh import make_cpu_mesh
    from repro.models.model import build_model
    from repro.models.sharding import ShardingRules

    mesh = make_cpu_mesh(1, 1)
    cfg_f = dataclasses.replace(get_smoke_config("yi-9b"), dtype="float32",
                                use_flash=True)
    cfg_n = dataclasses.replace(cfg_f, use_flash=False)
    rules = ShardingRules(mesh)
    m_f = build_model(cfg_f, rules)
    m_n = build_model(cfg_n, rules)
    params, _ = m_f.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg_f.vocab_size, (2, 64)),
        jnp.int32,
    )
    lf, _ = m_f.forward(params, toks)
    ln, _ = m_n.forward(params, toks)
    np.testing.assert_allclose(np.asarray(lf), np.asarray(ln),
                               rtol=2e-4, atol=2e-4)


def test_flash_preserves_bf16_dtype():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 32, 4, 16)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 32, 4, 16)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 32, 4, 16)), jnp.bfloat16)
    out = flash_attention(q, k, v, q_positions=jnp.arange(32),
                          kv_positions=jnp.arange(32), causal=True,
                          q_chunk=16, kv_chunk=16)
    assert out.dtype == jnp.bfloat16


def test_tp_pad_heads_exact():
    """Padded-head flash == naive attention on the original heads."""
    import types

    from repro.models.transformer import _tp_pad_heads

    rng = np.random.default_rng(5)
    B, S, H, KH, D = 2, 32, 5, 5, 8  # H=5 does not divide tp=4
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KH, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KH, D)), jnp.float32)
    fake_rules = types.SimpleNamespace(
        mesh=types.SimpleNamespace(shape={"model": 4}, size=1)
    )
    qp, kp, vp, H_orig = _tp_pad_heads(q, k, v, fake_rules)
    assert H_orig == H and qp.shape[2] == 8 and kp.shape[2] == 8
    o_pad = flash_attention(
        qp, kp, vp, q_positions=jnp.arange(S), kv_positions=jnp.arange(S),
        causal=True, q_chunk=16, kv_chunk=16,
    )[:, :, :H]
    o_ref = chunked_attention(
        q, k, v, q_positions=jnp.arange(S), kv_positions=jnp.arange(S),
        causal=True, q_chunk=16, kv_chunk=16,
    )
    np.testing.assert_allclose(np.asarray(o_pad), np.asarray(o_ref),
                               rtol=1e-5, atol=1e-5)


def test_tp_pad_heads_gqa_case():
    import types

    from repro.models.transformer import _tp_pad_heads

    rng = np.random.default_rng(6)
    B, S, H, KH, D = 1, 16, 6, 2, 4  # GQA G=3, H=6 vs tp=4 -> pad to 8
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KH, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KH, D)), jnp.float32)
    fake_rules = types.SimpleNamespace(
        mesh=types.SimpleNamespace(shape={"model": 4}, size=1)
    )
    qp, kp, vp, H_orig = _tp_pad_heads(q, k, v, fake_rules)
    assert qp.shape[2] == kp.shape[2] == 8
    o_pad = flash_attention(
        qp, kp, vp, q_positions=jnp.arange(S), kv_positions=jnp.arange(S),
        causal=True, q_chunk=8, kv_chunk=8,
    )[:, :, :H]
    o_ref = chunked_attention(
        q, k, v, q_positions=jnp.arange(S), kv_positions=jnp.arange(S),
        causal=True, q_chunk=8, kv_chunk=8,
    )
    np.testing.assert_allclose(np.asarray(o_pad), np.asarray(o_ref),
                               rtol=1e-5, atol=1e-5)

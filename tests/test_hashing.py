"""Hash parity (numpy vs jnp) and set-hash properties."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro.core import hashing as H


@given(st.lists(st.integers(1, 2**31 - 1), min_size=1, max_size=64), st.integers(0, 50))
@settings(max_examples=50, deadline=None)
def test_hash_parity_np_jnp(vals, seed):
    x = np.array(vals, dtype=np.int32)
    a = H.hash_u32(x, seed, xp=np)
    b = np.asarray(H.hash_u32(jnp.asarray(x), seed, xp=jnp))
    assert (a == b).all()


@given(
    st.lists(st.integers(1, 10**6), min_size=1, max_size=16),
    st.integers(0, 10),
)
@settings(max_examples=50, deadline=None)
def test_set_hash_permutation_invariant(vals, seed):
    x = np.array(vals, dtype=np.int32)
    v = np.ones(len(x), dtype=bool)
    h1 = H.set_hash(x, v, seed=seed, xp=np)
    rng = np.random.default_rng(0)
    perm = rng.permutation(len(x))
    h2 = H.set_hash(x[perm], v[perm], seed=seed, xp=np)
    assert h1 == h2
    h3 = np.asarray(H.set_hash(jnp.asarray(x), jnp.asarray(v), seed=seed, xp=jnp))
    assert h1 == h3


def test_set_hash_respects_mask():
    x = np.array([5, 9, 7, 7], dtype=np.int32)
    v = np.array([True, False, True, False])
    y = np.array([5, 7, 1, 2], dtype=np.int32)
    w = np.array([True, True, False, False])
    assert H.set_hash(x, v, xp=np) == H.set_hash(y, w, xp=np)


def test_hash_distribution_roughly_uniform():
    x = np.arange(1, 100001, dtype=np.int32)
    h = H.hash_u32(x, 0, xp=np)
    buckets = np.bincount((h % np.uint32(64)).astype(np.int64), minlength=64)
    assert buckets.max() / buckets.mean() < 1.2

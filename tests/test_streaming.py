"""Corpus-scale streaming: the single-launch DMA megakernel, host spill
streaming, and resumable shard merges.

Bit-parity contracts: the streamed launch (``ExtractParams(streamed=True)``
— in-kernel tile loop over a double-buffered DMA pipeline) must reproduce
the per-tile launch loop (``streamed=False``) bit for bit at every
geometry and scheme, ``spill_filter_compact`` over a file-backed corpus
must match the resident drivers field for field, and a killed-then-resumed
checkpointed run must merge to identical results. The HBM model's
``streamed=`` term and the checkpoint-manifest guard are pinned here too.
"""
import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.dictionary import PAD
from repro.extraction import engine as E
from repro.extraction import sharded as SH

GAMMA = 0.8
CAND_KEYS = ("win_tokens", "win_valid", "doc", "pos", "length",
             "n_survive", "overflow")


def _docs(rng, D, T, vocab=2048, pad_frac=0.15):
    d = rng.integers(1, vocab, size=(D, T)).astype(np.int32)
    d[rng.random((D, T)) < pad_frac] = PAD
    return jnp.asarray(d)


def _filter(rng, num_bits=1 << 14, density=0.3):
    w = (rng.random((num_bits // 32, 32)) < density).astype(np.uint32)
    bits = (w << np.arange(32, dtype=np.uint32)).sum(axis=1).astype(np.uint32)
    return (jnp.asarray(bits), num_bits, 3)


def _params(**kw):
    kw.setdefault("gamma", GAMMA)
    kw.setdefault("scheme", "prefix")
    kw.setdefault("use_kernel", True)
    return E.ExtractParams(**kw)


def _assert_cands_equal(got, want):
    for k in CAND_KEYS:
        np.testing.assert_array_equal(
            np.asarray(got[k]), np.asarray(want[k]), err_msg=k
        )
    if "variant_keys" in want:
        assert "variant_keys" in got
        for a, b in zip(got["variant_keys"], want["variant_keys"]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------- streamed vs per-tile parity
@pytest.mark.parametrize("scheme", ["word", "prefix", "lsh", "variant"])
def test_streamed_parity_schemes(scheme):
    """Every scheme, uneven geometry: streamed launch == per-tile loop.

    D=13 with tile_docs=3 forces a PAD-padded ragged tail AND a tile
    height that is not a multiple of the NC-derived sub-tile height, so
    the streamed buffer layout must replay the per-tile padding exactly.
    """
    rng = np.random.default_rng(21)
    docs = _docs(rng, 13, 96)
    flt = _filter(rng)
    per_tile = _params(scheme=scheme, max_candidates=256, streamed=False)
    streamed = _params(scheme=scheme, max_candidates=256, streamed=True)
    want = SH.stream_filter_compact(docs, 7, flt, per_tile, tile_docs=3)
    got = SH.stream_filter_compact(docs, 7, flt, streamed, tile_docs=3)
    _assert_cands_equal(got, want)
    assert int(want["n_survive"]) > 0  # non-vacuous
    # and both match the unsharded single-call fast path
    _assert_cands_equal(got, E.fused_filter_compact(
        docs, 7, flt, _params(scheme=scheme, max_candidates=256)))


def test_streamed_parity_raw_lanes():
    """stream_probe_tiles: raw counts/cands lanes identical bit for bit."""
    rng = np.random.default_rng(22)
    docs = _docs(rng, 16, 64)
    flt = _filter(rng)
    base = dict(max_candidates=128)
    c0, x0, _ = SH.stream_probe_tiles(
        docs, 6, flt, _params(streamed=False, **base), tile_docs=4)
    c1, x1, _ = SH.stream_probe_tiles(
        docs, 6, flt, _params(streamed=True, **base), tile_docs=4)
    np.testing.assert_array_equal(np.asarray(c0), np.asarray(c1))
    np.testing.assert_array_equal(np.asarray(x0), np.asarray(x1))


def test_streamed_parity_pad_only_tiles():
    """Tiles made entirely of PAD rows stream to empty lanes."""
    rng = np.random.default_rng(23)
    d = np.array(_docs(rng, 16, 64))
    d[4:12] = PAD  # tiles 1 and 2 (tile_docs=4) are PAD-only
    docs = jnp.asarray(d)
    flt = _filter(rng)
    want = SH.stream_filter_compact(
        docs, 6, flt, _params(max_candidates=256, streamed=False), tile_docs=4)
    got = SH.stream_filter_compact(
        docs, 6, flt, _params(max_candidates=256, streamed=True), tile_docs=4)
    _assert_cands_equal(got, want)
    assert not np.isin(np.asarray(got["doc"]), np.arange(4, 12)).any()


def test_streamed_parity_zero_survivors():
    """Empty filter: every chunk streams through, none emits."""
    rng = np.random.default_rng(24)
    docs = _docs(rng, 12, 64, pad_frac=0.0)
    flt = (jnp.zeros(((1 << 12) // 32,), jnp.uint32), 1 << 12, 3)
    want = SH.stream_filter_compact(
        docs, 6, flt, _params(max_candidates=128, streamed=False), tile_docs=4)
    got = SH.stream_filter_compact(
        docs, 6, flt, _params(max_candidates=128, streamed=True), tile_docs=4)
    _assert_cands_equal(got, want)
    assert int(got["n_survive"]) == 0


def test_streamed_count_only_parity():
    """The count-only sizing pass streams to identical per-tile counts."""
    rng = np.random.default_rng(25)
    docs = _docs(rng, 13, 96)
    flt = _filter(rng)
    want = SH.stream_tile_counts(
        docs, 7, flt, _params(max_candidates=128, streamed=False), tile_docs=3)
    got = SH.stream_tile_counts(
        docs, 7, flt, _params(max_candidates=128, streamed=True), tile_docs=3)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_streamed_adaptive_lanes_parity():
    """Two-pass adaptive sizing composes with the streamed launch."""
    rng = np.random.default_rng(26)
    docs = _docs(rng, 13, 96)
    flt = _filter(rng)
    want = SH.stream_filter_compact(
        docs, 7, flt,
        _params(max_candidates=256, adaptive_lanes=True, streamed=False),
        tile_docs=3)
    got = SH.stream_filter_compact(
        docs, 7, flt,
        _params(max_candidates=256, adaptive_lanes=True, streamed=True),
        tile_docs=3)
    _assert_cands_equal(got, want)


def test_resolve_streamed_auto_and_override():
    assert SH.resolve_streamed(_params(), 1) is False  # 1 tile: nothing to overlap
    assert SH.resolve_streamed(_params(), 2) is True
    assert SH.resolve_streamed(_params(streamed=True), 1) is True
    assert SH.resolve_streamed(_params(streamed=False), 8) is False


def test_streamed_requires_kernel_compact():
    with pytest.raises(ValueError, match="kernel_compact"):
        _params(streamed=True, use_kernel=False)
    with pytest.raises(ValueError, match="kernel_compact"):
        _params(streamed=True, kernel_compact=False)


# ------------------------------------------------- shard-geometry planning
def test_plan_shards_clamps_tiny_corpus():
    """Requested shard/tile heights larger than the corpus clamp down:
    a 3-doc corpus with shard_docs=64 must not pad every tile to 64."""
    spec = SH.plan_shards(3, n_workers=1, shard_docs=64, tile_docs=64)
    assert spec.shard_docs == 3
    assert spec.tile_docs == 3
    assert spec.num_shards == 1
    assert spec.tiles_per_shard == 1


def test_plan_shards_clamped_parity():
    """The clamped tiny-corpus geometry still merges bit-identically."""
    rng = np.random.default_rng(27)
    docs = _docs(rng, 3, 64)
    flt = _filter(rng)
    params = _params(max_candidates=128)
    want = E.fused_filter_compact(docs, 6, flt, params)
    got = SH.sharded_filter_compact(
        docs, 6, flt, params, shard_docs=64, tile_docs=64
    )
    _assert_cands_equal(got, want)


def test_shard_docs_for_budget_rule():
    """budget // (T * 4 * 2) rows, tile-aligned, floored at one tile."""
    T, td = 128, 64
    budget = 512 * T * 4 * 2  # exactly 512 rows of double-buffer headroom
    assert SH.shard_docs_for_budget(10_000, T, budget, td) == 512
    # non-tile-aligned budget rounds down to whole tiles
    assert SH.shard_docs_for_budget(10_000, T, budget - 1, td) == 512 - td
    # a budget below one tile still streams tile-sized shards
    assert SH.shard_docs_for_budget(10_000, T, 1, td) == td
    # and clamps to the corpus
    assert SH.shard_docs_for_budget(100, T, budget, td) == 100


# --------------------------------------------------- HBM model direction
def test_hbm_model_streamed_direction():
    from repro.kernels.fused_probe import hbm_bytes_fused, hbm_bytes_unfused

    kw = dict(kernel_compact=True)
    per_tile = hbm_bytes_fused(4096, 128, 8, 256, 4, False, **kw)
    streamed = hbm_bytes_fused(4096, 128, 8, 256, 4, False, streamed=True,
                               **kw)
    # streaming elides exactly the packed-bitmap write (D * T * 4 bytes)
    assert per_tile - streamed == 4096 * 128 * 4
    # the unfused pipeline has no term to elide (documented no-op)
    assert (hbm_bytes_unfused(4096, 128, 8, 256, 1, streamed=True)
            == hbm_bytes_unfused(4096, 128, 8, 256, 1))
    # streamed modeling without the lane epilogue is a contradiction
    with pytest.raises(ValueError, match="kernel_compact"):
        hbm_bytes_fused(4096, 128, 8, 256, 4, False, streamed=True)


def test_lane_plan_streamed_delta():
    from repro.core.cost_model import lane_plan

    plan = lane_plan(4096, 128, 8, 256, density=0.01, streamed=True)
    base = lane_plan(4096, 128, 8, 256, density=0.01, streamed=False)
    assert plan["streamed"] is True and base["streamed"] is False
    assert base["bytes_streamed_delta"] == 0  # per-tile plan: nothing elided
    best = min(plan["bytes_fixed"], plan["bytes_two_pass"])
    best_base = min(base["bytes_fixed"], base["bytes_two_pass"])
    assert plan["bytes_streamed_delta"] == best_base - best > 0


# ------------------------------------------------ checkpoints: resumable
def test_sharded_checkpoint_roundtrip(tmp_path):
    """Full run writes per-shard lanes; rerun loads them (no re-probe)."""
    rng = np.random.default_rng(31)
    docs = _docs(rng, 13, 96)
    flt = _filter(rng)
    params = _params(max_candidates=256)
    ckpt = str(tmp_path / "lanes")
    want = E.fused_filter_compact(docs, 7, flt, params)
    s1: dict = {}
    got = SH.sharded_filter_compact(
        docs, 7, flt, params, shard_docs=4, tile_docs=2,
        checkpoint_dir=ckpt, stream_stats=s1,
    )
    _assert_cands_equal(got, want)
    assert s1["checkpoint_writes"] == 4 and s1.get("checkpoint_hits", 0) == 0
    s2: dict = {}
    again = SH.sharded_filter_compact(
        docs, 7, flt, params, shard_docs=4, tile_docs=2,
        checkpoint_dir=ckpt, stream_stats=s2,
    )
    _assert_cands_equal(again, want)
    assert s2["checkpoint_hits"] == 4 and s2.get("checkpoint_writes", 0) == 0
    assert s2.get("streamed_launches", 0) == 0  # nothing re-probed


def test_spill_kill_then_resume(tmp_path):
    """Interrupted corpus job resumes from the last finished shard to
    bit-identical merged results."""
    rng = np.random.default_rng(32)
    docs = np.array(_docs(rng, 24, 64))
    flt = _filter(rng)
    params = _params(max_candidates=256)
    corpus = SH.MemmapCorpus.write(str(tmp_path / "corpus"), docs)
    ckpt = str(tmp_path / "lanes")
    want = E.fused_filter_compact(jnp.asarray(docs), 6, flt, params)

    with pytest.raises(RuntimeError, match="simulated interruption"):
        SH.spill_filter_compact(
            corpus, 6, flt, params, shard_docs=4, tile_docs=2,
            checkpoint_dir=ckpt, fail_after_shards=2,
        )
    # the kill left exactly 2 whole shard checkpoints (atomic writes)
    done = sorted(p.name for p in (tmp_path / "lanes").glob("shard_*.npz"))
    assert done == ["shard_000000.npz", "shard_000001.npz"]

    stats: dict = {}
    got = SH.spill_filter_compact(
        corpus, 6, flt, params, shard_docs=4, tile_docs=2,
        checkpoint_dir=ckpt, stream_stats=stats,
    )
    _assert_cands_equal(got, want)
    assert stats["checkpoint_hits"] == 2  # resumed, not re-probed
    assert stats["checkpoint_writes"] == 4  # only the remaining shards


def test_spill_kill_then_resume_variant(tmp_path):
    """Variant key payloads survive the checkpoint round trip."""
    rng = np.random.default_rng(33)
    docs = np.array(_docs(rng, 16, 64))
    flt = _filter(rng)
    params = _params(scheme="variant", max_candidates=256)
    corpus = SH.MemmapCorpus.write(str(tmp_path / "corpus"), docs)
    ckpt = str(tmp_path / "lanes")
    want = E.fused_filter_compact(jnp.asarray(docs), 6, flt, params)
    with pytest.raises(RuntimeError, match="simulated interruption"):
        SH.spill_filter_compact(
            corpus, 6, flt, params, shard_docs=4, tile_docs=2,
            checkpoint_dir=ckpt, fail_after_shards=1,
        )
    got = SH.spill_filter_compact(
        corpus, 6, flt, params, shard_docs=4, tile_docs=2,
        checkpoint_dir=ckpt,
    )
    _assert_cands_equal(got, want)
    assert "variant_keys" in got


def test_checkpoint_manifest_mismatch(tmp_path):
    """Resuming against a different filter/geometry raises, never merges."""
    rng = np.random.default_rng(34)
    docs = _docs(rng, 12, 64)
    flt = _filter(rng)
    params = _params(max_candidates=128)
    ckpt = str(tmp_path / "lanes")
    SH.sharded_filter_compact(docs, 6, flt, params, shard_docs=4,
                              tile_docs=2, checkpoint_dir=ckpt)
    other = _filter(np.random.default_rng(99))
    with pytest.raises(ValueError, match="manifest mismatch"):
        SH.sharded_filter_compact(docs, 6, other, params, shard_docs=4,
                                  tile_docs=2, checkpoint_dir=ckpt)
    with pytest.raises(ValueError, match="manifest mismatch"):
        SH.sharded_filter_compact(docs, 6, flt, params, shard_docs=6,
                                  tile_docs=2, checkpoint_dir=ckpt)
    # reset=True wipes the stale lanes and starts the new job over
    corpus = SH.MemmapCorpus(tokens=np.array(docs))
    got = SH.spill_filter_compact(
        corpus, 6, other, params, shard_docs=4, tile_docs=2,
        checkpoint_dir=ckpt, reset_checkpoints=True,
    )
    _assert_cands_equal(got, E.fused_filter_compact(docs, 6, other, params))


# -------------------------------------------------- spill streaming
def test_spill_over_budget_parity(tmp_path):
    """A corpus over the device budget completes via spill streaming and
    matches the resident path field for field."""
    rng = np.random.default_rng(35)
    docs = np.array(_docs(rng, 32, 64))
    flt = _filter(rng)
    params = _params(max_candidates=256)
    corpus = SH.MemmapCorpus.write(str(tmp_path / "corpus"), docs)
    # budget holds 4 docs of double-buffered staging: 8 shards of 4
    budget = 4 * 64 * 4 * 2
    stats: dict = {}
    got = SH.spill_filter_compact(
        corpus, 6, flt, params, device_budget_bytes=budget, tile_docs=2,
        stream_stats=stats,
    )
    _assert_cands_equal(got, E.fused_filter_compact(
        jnp.asarray(docs), 6, flt, params))
    # 8 staged shard regions of 4x64 int32 each crossed the host buffer
    assert stats["spill_bytes_staged"] == 8 * 4 * 64 * 4
    assert stats["streamed_launches"] == 8  # one launch per shard
    assert stats["tiles_streamed"] == stats["dma_waits"] > 8


def test_spill_accepts_host_arrays(tmp_path):
    """Plain in-memory [D, T] arrays duck-type as a MemmapCorpus."""
    rng = np.random.default_rng(36)
    docs = np.array(_docs(rng, 10, 64))
    flt = _filter(rng)
    params = _params(max_candidates=128)
    got = SH.spill_filter_compact(docs, 6, flt, params, shard_docs=3,
                                  tile_docs=2)
    _assert_cands_equal(got, E.fused_filter_compact(
        jnp.asarray(docs), 6, flt, params))


def test_memmap_corpus_roundtrip(tmp_path):
    rng = np.random.default_rng(37)
    docs = np.array(_docs(rng, 6, 32))
    c = SH.MemmapCorpus.write(str(tmp_path / "c"), docs)
    assert (c.rows, c.seq_len) == (6, 32)
    np.testing.assert_array_equal(np.asarray(c.tokens), docs)
    reopened = SH.MemmapCorpus.open(str(tmp_path / "c"))
    np.testing.assert_array_equal(np.asarray(reopened.tokens), docs)


def test_spill_requires_epilogue():
    rng = np.random.default_rng(38)
    docs = np.array(_docs(rng, 8, 64))
    with pytest.raises(ValueError, match="in-kernel compaction"):
        SH.spill_filter_compact(
            docs, 6, _filter(rng), _params(use_kernel=False),
        )


# ------------------------------------------------ serving observability
def test_shard_lane_steady_stream_stats():
    """Multi-tile serving probes report their streamed-launch counters."""
    rng = np.random.default_rng(39)
    docs = _docs(rng, 12, 64)
    flt = _filter(rng)
    stats: dict = {}
    lane, n, keys, tile_max, sizing = SH.shard_lane_steady(
        docs, 0, 6, flt, _params(max_candidates=128), tile_docs=4,
        stream_stats=stats,
    )
    assert sizing == "fixed" and keys is None
    assert stats["streamed_launches"] == 1
    assert stats["tiles_streamed"] == stats["dma_waits"] >= 3
    # pinning streamed=False leaves the counters untouched
    stats2: dict = {}
    lane2, n2, *_ = SH.shard_lane_steady(
        docs, 0, 6, flt, _params(max_candidates=128, streamed=False),
        tile_docs=4, stream_stats=stats2,
    )
    assert stats2 == {}
    np.testing.assert_array_equal(np.asarray(lane), np.asarray(lane2))
    assert int(n[0]) == int(n2[0])


def test_serving_metrics_record_stream():
    from repro.serving.metrics import ServingMetrics

    m = ServingMetrics()
    m.record_stream({"streamed_launches": 2, "tiles_streamed": 8,
                     "dma_waits": 8, "checkpoint_writes": 1})
    m.record_stream({})  # per-tile probe: a no-op
    m.record_stream({"streamed_launches": 1, "tiles_streamed": 4,
                     "dma_waits": 4, "checkpoint_hits": 3})
    s = m.summary()
    assert s["streamed_launches"] == 3
    assert s["tiles_streamed"] == 12
    assert s["dma_waits"] == 12
    assert s["checkpoint_writes"] == 1
    assert s["checkpoint_hits"] == 3


# ------------------------------------------------ end-to-end: eejoin
def test_execute_corpus_equals_execute(small_corpus, tmp_path):
    from repro.core.cost_model import OBJ_JOB, SideCost
    from repro.core.eejoin import EEJoinConfig, EEJoinOperator
    from repro.core.plan import Plan, PlanSide

    c = small_corpus
    op = EEJoinOperator(
        c.dictionary,
        EEJoinConfig(gamma=GAMMA, max_candidates=4096, result_capacity=8192,
                     use_kernel=True,
                     device_budget_bytes=3 * c.doc_tokens.shape[1] * 4 * 2),
    )
    z = SideCost(0, 0, 0, 0, 0, 0, 0, 0, 0)
    plan = Plan(0, PlanSide("index", "prefix"), PlanSide("ssjoin", "prefix"),
                OBJ_JOB, 0.0, z, z, 0)
    prepared = op.prepare(plan)
    docs = jnp.asarray(c.doc_tokens)
    want = op.execute(prepared, docs).to_set()
    corpus = SH.MemmapCorpus.write(str(tmp_path / "corpus"), c.doc_tokens)
    stats: dict = {}
    got = op.execute_corpus(
        prepared, corpus, tile_docs=2,
        checkpoint_dir=str(tmp_path / "ckpt"), stream_stats=stats,
    ).to_set()
    assert got == want
    assert stats["checkpoint_writes"] > 0
    # resume path: a second call consumes the checkpoints, same matches
    again = op.execute_corpus(
        prepared, corpus, tile_docs=2, checkpoint_dir=str(tmp_path / "ckpt"),
    ).to_set()
    assert again == want


def test_execute_corpus_kill_then_resume(small_corpus, tmp_path):
    from repro.core.cost_model import OBJ_JOB, SideCost
    from repro.core.eejoin import EEJoinConfig, EEJoinOperator
    from repro.core.plan import Plan, PlanSide

    c = small_corpus
    op = EEJoinOperator(
        c.dictionary,
        EEJoinConfig(gamma=GAMMA, max_candidates=4096, result_capacity=8192,
                     use_kernel=True),
    )
    z = SideCost(0, 0, 0, 0, 0, 0, 0, 0, 0)
    plan = Plan(0, PlanSide("ssjoin", "prefix"), PlanSide("ssjoin", "variant"),
                OBJ_JOB, 0.0, z, z, 0)
    prepared = op.prepare(plan)
    want = op.execute(prepared, jnp.asarray(c.doc_tokens)).to_set()
    corpus = SH.MemmapCorpus.write(str(tmp_path / "corpus"), c.doc_tokens)
    ckpt = str(tmp_path / "ckpt")
    with pytest.raises(RuntimeError, match="simulated interruption"):
        op.execute_corpus(prepared, corpus, shard_docs=2, tile_docs=2,
                          checkpoint_dir=ckpt, fail_after_shards=2)
    got = op.execute_corpus(prepared, corpus, shard_docs=2, tile_docs=2,
                            checkpoint_dir=ckpt).to_set()
    assert got == want

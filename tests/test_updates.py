"""Live dictionary updates: delta-built state ≡ from-scratch rebuild.

The subsystem's single contract, checked at every layer: prepared state
assembled incrementally (Bloom bit-union for adds, LSM delta segments
probed beside the base, tombstone masks at emit) must answer every
probed query exactly like a from-scratch rebuild over the live entity
set —

* filter: the unioned bitmap is bit-identical to a build over
  base ∪ adds, and never drops a token the live rebuild admits;
* sig tables / indexes: per-window candidate sets match;
* end to end: ``execute_epoch`` match sets equal the rebuild oracle's
  (with its local ids mapped back through ``id_map``) across random
  add/tombstone sequences, including empty and delete-only deltas,
  across schemes, algorithms and hybrid plans, and across compaction.

These seeded-random sequence tests always run; ``test_updates_prop.py``
re-states the core invariants property-based under hypothesis.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.cost_model import (
    MAINT_ABSORB,
    MAINT_COMPACT,
    MAINT_REBUILD,
    OBJ_JOB,
    CostParams,
    SideCost,
    maintenance_plan,
)
from repro.core.eejoin import EEJoinConfig, EEJoinOperator
from repro.core.filter import build_ish_filter, token_in_filter
from repro.core.plan import Plan, PlanSide
from repro.core.signatures import window_signatures
from repro.data.synth import make_corpus
from repro.extraction import engine as E
from repro.extraction.results import Matches, filter_matches
from repro.serving.session import pure_plan
from repro import updates as U

GAMMA = 0.8


def _config(**kw):
    kw.setdefault("gamma", GAMMA)
    kw.setdefault("max_candidates", 4096)
    kw.setdefault("result_capacity", 8192)
    kw.setdefault("use_kernel", True)
    return EEJoinConfig(**kw)


def _corpus(seed=0, num_entities=24, num_docs=8):
    return make_corpus(
        num_docs=num_docs, doc_len=64, vocab_size=512,
        num_entities=num_entities, seed=seed,
    )


def _hybrid_plan(split, head, tail):
    z = SideCost(0, 0, 0, 0, 0, 0, 0, 0, 0)
    return Plan(split, head, tail, OBJ_JOB, 0.0, z, z, 0)


def _initial(corpus, cfg, plan):
    op = EEJoinOperator(corpus.dictionary, cfg)
    prepared = op.prepare(plan)
    return U.initial_epoch(corpus.dictionary, plan, prepared)


def _matching_delta(rng, version, corpus, n_add, n_dead):
    """Delta whose adds are noisy copies of corpus entities (so the
    new entities actually occur in documents — parity on matches that
    exist, not just on empty sets)."""
    d = version.base
    adds = []
    for _ in range(n_add):
        i = int(rng.integers(0, d.num_entities))
        n = int(d.lengths[i])
        toks = [int(t) for t in d.tokens[i, :n]]
        if len(toks) > 1 and rng.random() < 0.5:
            toks = toks[:-1]  # drop a token: still a gamma-variant often
        adds.append(tuple(toks))
    live = np.nonzero(version.live_mask())[0]
    n_dead = min(n_dead, max(len(live) - 1, 0))
    tombs = rng.choice(live, size=n_dead, replace=False) if n_dead else []
    return U.DictionaryDelta(added=tuple(adds),
                             tombstones=tuple(int(t) for t in tombs))


# --------------------------------------------------------------------------
# delta / version mechanics
# --------------------------------------------------------------------------


def test_version_apply_ids_and_tombstones():
    corpus = _corpus()
    v0 = U.DictionaryVersion.initial(corpus.dictionary)
    E0 = v0.total_entities
    delta = U.DictionaryDelta(added=((5, 6), (7, 8, 9)), tombstones=(0, 3))
    v1 = v0.apply(delta)
    assert v1.epoch == 1
    assert v1.total_entities == E0 + 2
    assert v1.num_segments == 1
    assert v1.segment_offsets == (E0,)
    assert v1.tombstones[0] and v1.tombstones[3]
    assert not v1.tombstones[E0:].any()
    # base untouched (shared by reference)
    assert v1.base is v0.base
    # double delete raises
    with pytest.raises(ValueError, match="already dead"):
        v1.apply(U.DictionaryDelta(tombstones=(0,)))
    with pytest.raises(ValueError, match="out of range"):
        v1.apply(U.DictionaryDelta(tombstones=(E0 + 2,)))


def test_empty_delta_bumps_epoch_only():
    corpus = _corpus()
    v0 = U.DictionaryVersion.initial(corpus.dictionary)
    v1 = v0.apply(U.DictionaryDelta())
    assert v1.epoch == 1 and v1.num_segments == 0
    assert v1.total_entities == v0.total_entities
    np.testing.assert_array_equal(v1.tombstones, v0.tombstones)


def test_segment_validation():
    corpus = _corpus()
    v0 = U.DictionaryVersion.initial(corpus.dictionary)
    with pytest.raises(ValueError, match="PAD"):
        v0.apply(U.DictionaryDelta(added=((0, 1),)))
    with pytest.raises(ValueError, match="empty entity"):
        v0.apply(U.DictionaryDelta(added=((),)))
    with pytest.raises(ValueError, match="out of vocab"):
        v0.apply(U.DictionaryDelta(added=((10**6,),)))
    too_long = tuple(range(1, corpus.dictionary.max_len + 2))
    with pytest.raises(ValueError, match="max_len"):
        v0.apply(U.DictionaryDelta(added=(too_long,)))


def test_effective_dictionary_and_split():
    corpus = _corpus()
    v = U.DictionaryVersion.initial(corpus.dictionary)
    E0 = v.total_entities
    v = v.apply(U.DictionaryDelta(added=((5, 6),), tombstones=(1, 4, 10)))
    eff, id_map = v.effective_dictionary()
    assert eff.num_entities == E0 + 1 - 3
    assert id_map.tolist() == [i for i in range(E0 + 1) if i not in (1, 4, 10)]
    # rows preserved verbatim in global-id order
    rows, lens, _ = v.entity_rows()
    np.testing.assert_array_equal(eff.tokens, rows[id_map])
    # split shrinks by tombstones inside it; pure-head covers adds too
    assert v.effective_split(5) == 5 - 2  # ids 1, 4 dead below 5
    assert v.effective_split(0) == 0
    assert v.effective_split(E0) == v.num_live
    assert v.effective_split(E0 + 7) == v.num_live


def test_compact_renumbers_with_id_map():
    corpus = _corpus()
    v = U.DictionaryVersion.initial(corpus.dictionary)
    v = v.apply(U.DictionaryDelta(added=((5, 6), (9, 8)), tombstones=(2,)))
    v2, id_map = v.compact()
    assert v2.epoch == v.epoch + 1
    assert v2.num_segments == 0 and not v2.tombstones.any()
    assert v2.total_entities == v.num_live
    rows, _, _ = v.entity_rows()
    np.testing.assert_array_equal(v2.base.tokens, rows[id_map])


# --------------------------------------------------------------------------
# filter parity
# --------------------------------------------------------------------------


def test_union_filter_bit_identical_to_merged_build():
    corpus = _corpus()
    cfg = _config()
    plan = pure_plan("prefix")
    state = _initial(corpus, cfg, plan)
    rng = np.random.default_rng(7)
    for _ in range(3):
        delta = _matching_delta(rng, state.version, corpus, n_add=3, n_dead=2)
        state = U.absorb_delta(state, delta, cfg)
    # from-scratch filter over ALL entities (live + tombstoned): the
    # union never unsets delete bits, so this is the bit-exact target
    all_rows, all_lens, all_freq = state.version.entity_rows()
    from repro.core.dictionary import Dictionary

    full = Dictionary(
        tokens=all_rows, lengths=all_lens, freq=all_freq,
        token_weight=corpus.dictionary.token_weight,
        entity_weight=corpus.dictionary.token_weight[all_rows].sum(axis=1),
    )
    want = build_ish_filter(full, cfg.gamma, num_bits=cfg.filter_bits)
    got_words = state.sides[-1].filter_words
    np.testing.assert_array_equal(got_words, want.bits)
    # soundness vs the live rebuild: every member token of the live
    # filter probes positive in the union (no false negatives, ever)
    eff, _ = state.version.effective_dictionary()
    live_f = build_ish_filter(eff, cfg.gamma, num_bits=cfg.filter_bits)
    hit = token_in_filter(
        jnp.asarray(got_words), want.num_bits, want.num_hashes,
        jnp.asarray(live_f.member_tokens),
    )
    assert bool(np.asarray(hit).all())


# --------------------------------------------------------------------------
# structure-level query parity (sig tables + indexes)
# --------------------------------------------------------------------------


def _window_batch(corpus, max_len):
    """Compacted candidate windows off the real corpus (no filter)."""
    docs = jnp.asarray(corpus.doc_tokens)
    base, surv = E.survival_mask(docs, max_len, None, False)
    return E.compact_candidates(base, surv, 2048)


def _probe_entities(cands, prepared_sides, scheme, live, id_space):
    """Global live entity-id sets per window across prepared sides."""
    toks, ok = cands["win_tokens"], cands["win_valid"]
    sigs, mask = window_signatures(scheme, toks, toks != 0, GAMMA)
    out = [set() for _ in range(toks.shape[0])]
    for side in prepared_sides:
        ents = np.asarray(
            E.probe_sig_table(side.sig_table, sigs, mask & ok[:, None])
        )
        ents = np.where(ents >= 0, ents + side.sig_table.entity_offset, -1)
        for w, row in enumerate(ents):
            for e in row[row >= 0]:
                if live[int(e)] if id_space == "global" else True:
                    out[w].add(int(e))
    return out


@pytest.mark.parametrize("scheme", ["word", "prefix", "lsh", "variant"])
def test_sig_table_query_parity(scheme):
    corpus = _corpus(seed=3)
    cfg = _config()
    plan = pure_plan(scheme)
    state = _initial(corpus, cfg, plan)
    rng = np.random.default_rng(11)
    for _ in range(2):
        delta = _matching_delta(rng, state.version, corpus, n_add=3, n_dead=1)
        state = U.absorb_delta(state, delta, cfg)
    cands = _window_batch(corpus, state.max_len)
    live = state.version.live_mask()
    got = _probe_entities(
        cands, state.sides[-1].all_sides(), scheme, live, "global"
    )
    op, prepared, id_map = U.rebuild_oracle(state.version, cfg, plan)
    want_local = _probe_entities(
        cands, prepared.sides, scheme, None, "local"
    )
    want = [{int(id_map[e]) for e in s} for s in want_local]
    assert got == want


@pytest.mark.parametrize("kind", ["word", "prefix", "variant"])
def test_index_query_parity(kind):
    from repro.core.index import query_inverted, query_variant
    from repro.core.variants import window_variant_key

    corpus = _corpus(seed=4)
    cfg = _config()
    plan = _hybrid_plan(10**9, PlanSide("index", kind), PlanSide("index", kind))
    state = _initial(corpus, cfg, plan)
    rng = np.random.default_rng(13)
    for _ in range(2):
        delta = _matching_delta(rng, state.version, corpus, n_add=3, n_dead=1)
        state = U.absorb_delta(state, delta, cfg)
    cands = _window_batch(corpus, state.max_len)
    toks, ok = cands["win_tokens"], cands["win_valid"]

    def probe(sides, live, id_map):
        out = [set() for _ in range(toks.shape[0])]
        for side in sides:
            for part in side.index_parts:
                if kind == "variant":
                    k1, k2 = window_variant_key(toks, toks != 0, xp=jnp)
                    ents = query_variant(
                        part.keys1, part.keys2, part.ents, part.n_buckets,
                        k1, k2,
                    )
                else:
                    ents = query_inverted(part.postings, toks, toks != 0)
                ents = np.asarray(jnp.where(ok[:, None], ents, -1))
                ents = np.where(ents >= 0, ents + part.entity_offset, -1)
                for w, row in enumerate(ents):
                    for e in row[row >= 0]:
                        g = int(e) if id_map is None else int(id_map[int(e)])
                        if live is None or live[g]:
                            out[w].add(g)
        return out

    live = state.version.live_mask()
    got = probe(state.sides[-1].all_sides(), live, None)
    op, prepared, id_map = U.rebuild_oracle(state.version, cfg, plan)
    want = probe(prepared.sides, None, id_map)
    assert got == want


# --------------------------------------------------------------------------
# end-to-end extraction parity over random delta sequences
# --------------------------------------------------------------------------


def _check_sequence(plan, cfg, seed, steps=4, scheme_docs_seed=0):
    corpus = _corpus(seed=scheme_docs_seed, num_entities=24)
    docs = jnp.asarray(corpus.doc_tokens)
    state = _initial(corpus, cfg, plan)
    rng = np.random.default_rng(seed)
    for step in range(steps):
        delta = _matching_delta(
            rng, state.version, corpus,
            n_add=int(rng.integers(0, 4)), n_dead=int(rng.integers(0, 3)),
        )
        state = U.absorb_delta(state, delta, cfg)
        got = U.epoch_matches(state, docs, cfg)
        want = U.oracle_matches(state.version, cfg, plan, docs)
        assert got == want, (
            f"step {step} ({delta.num_added} adds, "
            f"{delta.num_tombstoned} tombstones): {len(got)} vs {len(want)}"
        )
    return state


@pytest.mark.parametrize("scheme", ["word", "prefix", "lsh", "variant"])
def test_extraction_parity_ssjoin(scheme):
    _check_sequence(pure_plan(scheme), _config(), seed=21)


@pytest.mark.parametrize("kind", ["word", "prefix", "variant"])
def test_extraction_parity_index(kind):
    _check_sequence(
        pure_plan(kind, algo="index"), _config(), seed=22
    )


def test_extraction_parity_hybrid_plan():
    plan = _hybrid_plan(
        12, PlanSide("index", "prefix"), PlanSide("ssjoin", "prefix")
    )
    _check_sequence(plan, _config(), seed=23)


def test_extraction_parity_unfused_path():
    _check_sequence(pure_plan("prefix"), _config(use_kernel=False), seed=24)


def test_operator_execute_epoch_wrapper():
    """The eejoin-level entry point delegates to the versioned execute."""
    corpus = _corpus(seed=9)
    cfg = _config()
    plan = pure_plan("prefix")
    op = EEJoinOperator(corpus.dictionary, cfg)
    state = U.initial_epoch(corpus.dictionary, plan, op.prepare(plan))
    rng = np.random.default_rng(51)
    state = U.absorb_delta(
        state, _matching_delta(rng, state.version, corpus, 2, 1), cfg
    )
    docs = jnp.asarray(corpus.doc_tokens)
    assert op.execute_epoch(state, docs).to_set() == U.epoch_matches(
        state, docs, cfg
    )


def test_delete_only_and_empty_deltas():
    corpus = _corpus(seed=5)
    docs = jnp.asarray(corpus.doc_tokens)
    cfg = _config()
    plan = pure_plan("prefix")
    state = _initial(corpus, cfg, plan)
    base_set = U.epoch_matches(state, docs, cfg)

    empty = U.absorb_delta(state, U.DictionaryDelta(), cfg)
    assert empty.epoch == 1 and empty.open_segments == 0
    assert U.epoch_matches(empty, docs, cfg) == base_set

    # delete-only: tombstone every entity that matched something
    hit_ents = sorted({e for (_, _, _, e) in base_set})[:4]
    dead = U.absorb_delta(
        empty, U.DictionaryDelta(tombstones=tuple(hit_ents)), cfg
    )
    got = U.epoch_matches(dead, docs, cfg)
    want = {m for m in base_set if m[3] not in hit_ents}
    assert got == want
    assert got == U.oracle_matches(dead.version, cfg, plan, docs)


def test_compaction_preserves_results_modulo_id_map():
    cfg = _config()
    plan = pure_plan("prefix")
    state = _check_sequence(plan, cfg, seed=31, steps=3)
    corpus = _corpus(seed=0, num_entities=24)
    docs = jnp.asarray(corpus.doc_tokens)
    before = U.epoch_matches(state, docs, cfg)
    state2, _op = U.compact_epoch(state, cfg)
    assert state2.open_segments == 0 and not state2.has_tombstones
    after = U.epoch_matches(state2, docs, cfg)
    mapped = {(d, p, l, int(state2.id_map[e])) for (d, p, l, e) in after}
    assert mapped == before
    # and the compacted state keeps matching its own oracle
    assert after == U.oracle_matches(state2.version, cfg, state2.plan, docs)


def test_rebuild_epoch_replans_with_stats():
    corpus = _corpus(seed=6)
    docs = jnp.asarray(corpus.doc_tokens)
    # restrict the re-plan search to complete, verified schemes: the
    # parity claim is per-plan — a re-plan that picks lsh (probabilistic
    # recall) would legitimately change the match set
    cfg = _config(options=(("index", "prefix"), ("ssjoin", "prefix"),
                           ("index", "word"), ("ssjoin", "word")))
    state = _initial(corpus, cfg, pure_plan("prefix"))
    rng = np.random.default_rng(41)
    delta = _matching_delta(rng, state.version, corpus, n_add=3, n_dead=2)
    state = U.absorb_delta(state, delta, cfg)
    before = U.epoch_matches(state, docs, cfg)
    state2, op2 = U.rebuild_epoch(
        state, cfg, CostParams(num_devices=1), corpus.doc_tokens
    )
    # re-sorted base: frequency descending (Lemma 1's invariant back)
    freq = state2.version.base.freq
    assert (np.diff(freq) <= 1e-6).all()
    assert state2.plan.evaluations > 0  # a real §5 search ran
    after = U.epoch_matches(state2, docs, cfg)
    mapped = {(d, p, l, int(state2.id_map[e])) for (d, p, l, e) in after}
    assert mapped == before


# --------------------------------------------------------------------------
# emit-mask + maintenance units
# --------------------------------------------------------------------------


def test_filter_matches_masks_tombstoned():
    m = Matches(
        doc=jnp.asarray([0, 0, 1, -1], jnp.int32),
        pos=jnp.asarray([1, 2, 3, -1], jnp.int32),
        length=jnp.asarray([2, 2, 1, -1], jnp.int32),
        entity=jnp.asarray([0, 1, 2, -1], jnp.int32),
        score=jnp.asarray([1.0, 1.0, 0.9, 0.0], jnp.float32),
        count=jnp.asarray(3, jnp.int32),
    )
    live = jnp.asarray([True, False, True])
    out = filter_matches(m, live, 4)
    assert out.to_set() == {(0, 1, 2, 0), (1, 3, 1, 2)}
    assert int(out.count) == 2


def test_maintenance_plan_actions():
    cp = CostParams(num_devices=1)
    # big dictionary, short horizon -> absorbing the small delta wins
    p = maintenance_plan(
        cp, live_entities=100_000, delta_entities=100, open_segments=1,
        dead_entities=0, total_entities=100_000, probes_per_batch=4096,
        horizon_batches=10,
    )
    assert p.action == MAINT_ABSORB
    # long horizon: accumulated per-batch segment overhead dwarfs the
    # one-time fold -> compact
    p = maintenance_plan(
        cp, live_entities=100_000, delta_entities=100, open_segments=8,
        dead_entities=20_000, total_entities=120_000,
        probes_per_batch=4096, horizon_batches=10_000_000,
    )
    assert p.action == MAINT_COMPACT
    assert p.compact_s > p.absorb_s
    # stat drift past threshold forces the full re-plan
    p = maintenance_plan(
        cp, live_entities=1000, delta_entities=10, open_segments=1,
        dead_entities=0, total_entities=1000, probes_per_batch=4096,
        horizon_batches=10, stat_drift=0.9,
    )
    assert p.action == MAINT_REBUILD


def test_maintenance_overhead_monotone_in_segments_and_dead():
    from repro.core.cost_model import maintenance_overhead_per_batch

    cp = CostParams(num_devices=1)
    base = maintenance_overhead_per_batch(cp, 4096, 0, 0, 1000)
    seg = maintenance_overhead_per_batch(cp, 4096, 3, 0, 1000)
    dead = maintenance_overhead_per_batch(cp, 4096, 3, 500, 1000)
    assert base == 0.0 and seg > base and dead > seg

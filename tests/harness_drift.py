"""Drift-injection harness for the continuous-calibration tests.

Builds a deterministic two-phase serving workload on a virtual clock:
one shared dictionary, per-phase document streams with shifted mention
frequency / document length / dictionary skew (``repro.data.synth.
drift_docs``), and a service driver that keeps batches in flight across
a replan swap. The engineered cost model (``drift_cost_params``) scales
the index-probe constants so the §5 search robustly prefers
``index:prefix`` pricing-wise *not at all* — making ``ssjoin:prefix``
the unambiguous post-drift winner — while both options live in the same
similarity-semantics class, so every plan the replanner may install
computes the identical match set (serving stays bit-comparable to
``one_shot_reference`` across the swap).

Used by ``tests/test_replan.py`` and mirrored (without pytest) by
``benchmarks/bench_replan.py``.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.cost_model import CostParams
from repro.core.eejoin import EEJoinConfig
from repro.data.synth import drift_docs, skewed_mention_probs
from repro.serving import (
    BatcherConfig,
    ExtractionService,
    ReplanConfig,
    SessionCache,
    make_pools,
)
from repro.serving.session import pure_plan

NUM_ENTITIES = 24
# index-probe constants scaled 100x: a synthetic host where the padded
# index is expensive, so the post-drift search flips to ssjoin:prefix
# with a ~3x cost margin (robust to sampling noise on the doc ring)
INDEX_COST_SCALE = 100.0


class SimClock:
    """Monotonic virtual clock; the tests advance it explicitly."""

    def __init__(self):
        self.t = 0.0

    def advance(self, dt: float) -> float:
        self.t += dt
        return self.t

    def __call__(self) -> float:
        return self.t


@dataclasses.dataclass(frozen=True)
class Phase:
    """One leg of a drift workload (all knobs the paper's stats track)."""

    num_docs: int
    doc_len: int
    mention_kind: str  # skewed_mention_probs kind, or "none"
    mentions_per_doc: float
    seed: int


# phase A: short docs, head-skewed sparse mentions — the distribution
# the session's plan was (notionally) chosen under
PHASE_A = Phase(num_docs=48, doc_len=48, mention_kind="head",
                mentions_per_doc=0.5, seed=11)
# phase B: doubled doc length, tail-skewed dense mentions — every drift
# axis (doc_len, survivor density, dictionary skew) shifts at once
PHASE_B = Phase(num_docs=64, doc_len=96, mention_kind="tail",
                mentions_per_doc=6.0, seed=12)


def phase_docs(dictionary, phase: Phase) -> np.ndarray:
    probs = (None if phase.mention_kind == "none"
             else skewed_mention_probs(dictionary.num_entities,
                                       phase.mention_kind))
    return drift_docs(
        dictionary,
        num_docs=phase.num_docs,
        doc_len=phase.doc_len,
        mention_probs=probs,
        mentions_per_doc=phase.mentions_per_doc,
        seed=phase.seed,
    )


def drift_config() -> EEJoinConfig:
    # capacities sized for the *one-shot reference* over the full
    # two-phase doc set (a single execute sees every candidate window
    # at once; undersized lanes would silently overflow the reference)
    return EEJoinConfig(
        use_kernel=True,
        max_candidates=32768,
        result_capacity=16384,
        options=(("index", "prefix"), ("ssjoin", "prefix")),
        observe_capacity=64,
    )


def drift_cost_params() -> CostParams:
    base = CostParams(num_devices=1)
    return dataclasses.replace(
        base,
        c_probe_index=base.c_probe_index * INDEX_COST_SCALE,
        c_verify_index=base.c_verify_index * INDEX_COST_SCALE,
    )


def drift_replan_config(**overrides) -> ReplanConfig:
    """Inline (tick-driven) replanner tuned for the two-phase workload.

    ``refit=False`` keeps the plan-convergence assertion deterministic
    (refit folds in measured wall times, which vary run to run);
    ``time_drift=inf`` disables the wall-time trigger for the same
    reason. The fast EWMA halflife makes the density/doc-len estimators
    converge within the first post-shift batch, so the baseline reset
    after the swap lands on phase-B values and no second trigger fires.
    """
    kw = dict(
        thread=False,
        refit=False,
        min_batches=3,
        cooldown_batches=2,
        density_drift=0.5,
        doc_len_drift=0.5,
        time_drift=float("inf"),
        halflife_windows=200.0,
    )
    kw.update(overrides)
    return ReplanConfig(**kw)


def build_session(dictionary, config=None, cost_params=None):
    """Session forced onto ``pure index:prefix`` under the engineered
    cost model — the stale plan the drift leg replans away from."""
    cache = SessionCache()
    sess = cache.get_or_create(
        dictionary,
        config or drift_config(),
        plan=pure_plan("prefix", algo="index"),
        cost_params=cost_params or drift_cost_params(),
    )
    return cache, sess


def run_phases(
    cache,
    sess,
    phases_docs,
    replan_cfg: ReplanConfig | None,
    *,
    batch_docs: int = 8,
    rate: float = 600.0,
    wait_for_swap: bool = False,
    wait_for_swap_at: int | None = None,
    wait_timeout_s: float = 90.0,
    overlap: bool = True,
):
    """Serve the phases back-to-back; returns ``(service, all_docs)``.

    The stream drains between phases so the baseline freezes on pure
    phase-A telemetry; within the final phase, submission never waits
    on completion. With ``wait_for_swap`` the virtual clock keeps
    ticking (real-time bounded) until the replanner's swap lands —
    at ``wait_for_swap_at`` documents *into the final phase* when set
    (so the remaining documents are admitted on the post-swap epoch:
    batches run before, in flight across, and after the swap), else
    after the final phase is fully submitted.
    """
    clock = SimClock()
    svc = ExtractionService(
        cache,
        pools=make_pools(),
        batcher_config=BatcherConfig(max_batch_docs=batch_docs,
                                     max_delay_s=0.01),
        queue_capacity=4096,
        overlap=overlap,
        clock=clock,
        replan=replan_cfg,
    )
    all_docs: list[np.ndarray] = []
    gap = 1.0 / rate

    def await_swap():
        deadline = time.monotonic() + wait_timeout_s
        while (svc.metrics.replan_swaps == 0
               and time.monotonic() < deadline):
            svc.tick(now=clock.advance(1e-3))
            time.sleep(2e-3)

    with svc:
        doc_id = 0
        for p, docs in enumerate(phases_docs):
            final = p == len(phases_docs) - 1
            for j, row in enumerate(docs):
                if final and wait_for_swap and j == wait_for_swap_at:
                    await_swap()
                svc.submit(doc_id, row, sess.key, now=clock.advance(gap))
                svc.tick(now=clock.t)
                doc_id += 1
                all_docs.append(row)
            if not final:
                # phase boundary: let this phase's telemetry land fully,
                # then give the inline replanner steps to see it (first
                # step freezes the baseline)
                svc.drain()
                svc.tick(now=clock.t)
                svc.tick(now=clock.t)
        if wait_for_swap and svc.metrics.replan_swaps == 0:
            await_swap()
        svc.drain()
        svc.tick(now=clock.t)
    return svc, all_docs

"""End-to-end LM training driver with the EE-Join annotation stage.

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--wide]

Trains a decoder-only LM (reduced olmo-family config; --wide uses a
~100M-parameter d=768/12L config — the assignment's end-to-end scale,
a few hundred steps of which are CPU-feasible but slow) on the synthetic
corpus. The data pipeline runs the paper's operator first: every batch
carries an ``entity_mask`` tagging dictionary-entity mentions, and the
loss up-weights entity tokens (entity-aware training, one of the
operator's production uses). Checkpoints + deterministic resume come
from the shared trainer (kill + relaunch with --resume to test).
"""
import argparse
import dataclasses

import jax

from repro.configs.registry import get_smoke_config
from repro.core.cost_model import CostParams
from repro.core.eejoin import EEJoinConfig, EEJoinOperator
from repro.data.pipeline import PipelineConfig, batches
from repro.data.synth import make_corpus
from repro.launch.mesh import make_cpu_mesh
from repro.models.model import build_model
from repro.models.sharding import ShardingRules
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import TrainerConfig, train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--wide", action="store_true", help="~100M-param config")
ap.add_argument("--resume", action="store_true")
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
args = ap.parse_args()

cfg = get_smoke_config("olmo-1b")
if args.wide:
    cfg = dataclasses.replace(
        cfg, num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
        d_ff=3072, vocab_size=32768,
    )
mesh = make_cpu_mesh(1, 1)
model = build_model(cfg, ShardingRules(mesh))
n_params = sum(
    int(x.size) for x in jax.tree.leaves(
        jax.eval_shape(lambda k: model.init(k)[0], jax.random.PRNGKey(0))
    )
)
print(f"model: {cfg.num_layers}L d={cfg.d_model} -> {n_params/1e6:.1f}M params")

corpus = make_corpus(
    num_docs=128, doc_len=256, vocab_size=cfg.vocab_size, num_entities=96,
    mention_dist="zipf", mentions_per_doc=3.0, seed=0,
)
op = EEJoinOperator(corpus.dictionary, EEJoinConfig(gamma=0.8))
stats = op.gather_statistics(corpus.doc_tokens[:16], total_docs=128)
plan = op.choose_plan(stats, CostParams(num_devices=1))
prepared = op.prepare(plan)
print(f"EE-Join plan: {plan.head.algo}:{plan.head.scheme}|"
      f"{plan.tail.algo}:{plan.tail.scheme}@{plan.split}")

data = batches(
    corpus, PipelineConfig(seq_len=128, global_batch=8, annotate=True),
    op, prepared,
)
out = train(
    model, data,
    AdamWConfig(lr=3e-4, total_steps=args.steps, warmup_steps=20),
    TrainerConfig(total_steps=args.steps, log_every=max(args.steps // 10, 1),
                  checkpoint_every=100, checkpoint_dir=args.ckpt_dir),
    mesh, resume=args.resume,
)
first, last = out["history"][0], out["history"][-1]
print(f"loss: {first['loss']:.3f} (step {first['step']}) -> "
      f"{last['loss']:.3f} (step {last['step']})")
assert last["loss"] < first["loss"], "training must reduce loss"
print("ok")

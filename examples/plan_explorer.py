"""Plan-space explorer: how the chosen plan moves with the inputs.

    PYTHONPATH=src python examples/plan_explorer.py

Sweeps the knobs the paper identifies as decision drivers — mention
distribution, similarity threshold γ, device count, and HBM budget —
and prints which plan the cost model picks for each setting, plus the
predicted cost curve across split points for one illustrative pair
(the curve the §5.2 search descends).
"""
import numpy as np

from repro.core.cost_model import (
    ALGO_INDEX, ALGO_SSJOIN, OBJ_JOB, CostParams, cost_side, objective_value,
)
from repro.core.eejoin import EEJoinConfig, EEJoinOperator
from repro.core.plan import PlanSide
from repro.data.synth import MENTION_DISTS, make_corpus

E = 256
print(f"{'dist':8s} {'gamma':5s} {'devs':4s} {'budget':8s}  chosen plan")
for dist in MENTION_DISTS:
    corpus = make_corpus(
        num_docs=32, doc_len=160, vocab_size=8192, num_entities=E,
        mention_dist=dist, mentions_per_doc=4.0, seed=5,
    )
    for gamma in (0.6, 0.9):
        op = EEJoinOperator(corpus.dictionary, EEJoinConfig(gamma=gamma))
        stats = op.gather_statistics(corpus.doc_tokens[:16], total_docs=32)
        for devs, budget in ((1, 2e5), (256, 2e4), (256, 5e4)):
            plan = op.choose_plan(
                stats, CostParams(num_devices=devs, hbm_budget_bytes=budget)
            )
            print(f"{dist:8s} {gamma:5.2f} {devs:4d} {budget:8.0e}  "
                  f"{plan.head.algo}:{plan.head.scheme} | "
                  f"{plan.tail.algo}:{plan.tail.scheme} @ {plan.split:4d} "
                  f"cost={plan.predicted_cost:.2e}s")

# the split-cost curve for one pair (what the binary search walks)
corpus = make_corpus(num_docs=32, doc_len=160, vocab_size=8192,
                     num_entities=E, mention_dist="zipf", seed=5)
op = EEJoinOperator(corpus.dictionary, EEJoinConfig(gamma=0.8))
stats = op.gather_statistics(corpus.doc_tokens[:16], total_docs=32)
cp = CostParams(num_devices=256, hbm_budget_bytes=2e4)
head, tail = PlanSide(ALGO_INDEX, "variant"), PlanSide(ALGO_SSJOIN, "prefix")
print(f"\nsplit-cost curve for {head.algo}:{head.scheme} | "
      f"{tail.algo}:{tail.scheme} (E={E}):")
for p in range(0, E + 1, E // 8):
    hc = cost_side(stats, cp, 0, p, head.algo, head.scheme, head=True)
    tc = cost_side(stats, cp, p, E, tail.algo, tail.scheme, head=False)
    c = objective_value(hc, OBJ_JOB) + objective_value(tc, OBJ_JOB)
    bar = "#" * int(min(c, 2e-2) / 2e-2 * 50)
    print(f"  p={p:4d}  {c:.3e}s  {bar}")

"""Batched serving with EE-Join output annotation.

    PYTHONPATH=src python examples/serve_lm.py

Serves a small decoder LM with continuous batching (fixed slots, queue
refill) and runs the EE-Join operator over the generations as a
serve-time annotation stage — the operator's third production surface
besides offline extraction and train-pipeline tagging.
"""
import numpy as np

import jax

from repro.configs.registry import get_smoke_config
from repro.core.cost_model import CostParams
from repro.core.eejoin import EEJoinConfig, EEJoinOperator
from repro.data.synth import make_corpus
from repro.launch.mesh import make_cpu_mesh
from repro.models.model import build_model
from repro.models.sharding import ShardingRules
from repro.serve.engine import Request, ServeEngine

cfg = get_smoke_config("recurrentgemma-9b")  # hybrid arch: rglru + local attn
mesh = make_cpu_mesh(1, 1)
model = build_model(cfg, ShardingRules(mesh))
params, _ = model.init(jax.random.PRNGKey(0))

eng = ServeEngine(model, params, batch_slots=4, max_len=96)
rng = np.random.default_rng(0)
reqs = [
    Request(prompt=rng.integers(1, cfg.vocab_size, size=8).tolist(),
            max_new_tokens=12)
    for _ in range(10)
]
for r in reqs:
    eng.submit(r)
eng.run()
print(f"served {sum(r.done for r in reqs)}/{len(reqs)} requests "
      f"on arch={cfg.name} (blocks={cfg.block_pattern})")

# annotate generations with dictionary mentions
corpus = make_corpus(num_docs=4, doc_len=64, vocab_size=cfg.vocab_size,
                     num_entities=48, seed=2)
op = EEJoinOperator(corpus.dictionary, EEJoinConfig(gamma=0.8))
plan = op.choose_plan(op.gather_statistics(corpus.doc_tokens, total_docs=4),
                      CostParams(num_devices=1))
prepared = op.prepare(plan)
gen = np.zeros((len(reqs), 24), np.int32)
for i, r in enumerate(reqs):
    toks = (r.prompt + r.out)[:24]
    gen[i, : len(toks)] = toks
m = op.execute(prepared, gen)
print(f"EE-Join on generations: {int((np.asarray(m.doc) >= 0).sum())} mentions; "
      f"plan {plan.head.algo}:{plan.head.scheme}|{plan.tail.algo}:{plan.tail.scheme}")
for r in reqs[:2]:
    print(f"  prompt={r.prompt} -> {r.out}")

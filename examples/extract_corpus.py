"""End-to-end distributed extraction driver (the paper's production job).

    PYTHONPATH=src python examples/extract_corpus.py [n_fake_devices]

Runs the full EE-Join pipeline the way a cluster job would:
  1. distributed statistics gathering over document shards,
  2. cost-based plan search under the *job-completion* objective with
     the mesh's device count in the cost model,
  3. hybrid plan execution with the signature-keyed all_to_all shuffle,
  4. verification against the oracle + shuffle diagnostics (bytes,
     skew, overflow) — the quantities the cost model predicts.

The device count is faked on CPU (same mechanism as the dry-run); on a
real slice the identical code runs on the pod mesh.
"""
import os
import sys

N_DEV = int(sys.argv[1]) if len(sys.argv) > 1 else 8
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={N_DEV} "
    + os.environ.get("XLA_FLAGS", "")
)

import numpy as np  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.cost_model import CostParams, OBJ_JOB  # noqa: E402
from repro.core.eejoin import EEJoinConfig, EEJoinOperator  # noqa: E402
from repro.data.synth import make_corpus  # noqa: E402
from repro.extraction.oracle import oracle_extract  # noqa: E402
from repro.launch.mesh import make_extraction_mesh  # noqa: E402

GAMMA = 0.8

corpus = make_corpus(
    num_docs=max(32, 4 * N_DEV), doc_len=128, vocab_size=4096,
    num_entities=128, mention_dist="zipf", mentions_per_doc=4.0, seed=3,
)
docs = jnp.asarray(corpus.doc_tokens)
mesh = make_extraction_mesh(N_DEV)
print(f"mesh: {N_DEV} devices; corpus {corpus.doc_tokens.shape}")

op = EEJoinOperator(
    corpus.dictionary,
    EEJoinConfig(gamma=GAMMA, objective=OBJ_JOB,
                 max_candidates=16384, result_capacity=32768),
)
cp = CostParams(num_devices=N_DEV, hbm_budget_bytes=2e5)

stats = op.gather_statistics(corpus.doc_tokens[: max(8, N_DEV)],
                             total_docs=len(corpus.doc_tokens))
plan = op.choose_plan(stats, cp)
print(f"plan: head={plan.head.algo}:{plan.head.scheme} "
      f"tail={plan.tail.algo}:{plan.tail.scheme} split={plan.split}/"
      f"{corpus.dictionary.num_entities} predicted={plan.predicted_cost:.2e}s")

prepared = op.prepare_distributed(plan, N_DEV, cp)
with mesh:
    matches, diags = op.execute_distributed(prepared, docs, mesh, ("workers",))

got = set().union(*[m.to_set() for m in matches])
truth = oracle_extract(corpus.doc_tokens, corpus.dictionary, GAMMA, "extra")
tv = oracle_extract(corpus.doc_tokens, corpus.dictionary, GAMMA, "variant_exact")
want = set()
for side, a, b in ((plan.head, 0, plan.split),
                   (plan.tail, plan.split, corpus.dictionary.num_entities)):
    t = tv if side.scheme == "variant" else truth
    want |= {x for x in t if a <= x[3] < b}
print(f"matches: {len(got)}; exact-vs-oracle: {got == want}")
for d in diags:
    if d is not None:
        print(f"shuffle: {int(d.bytes_shuffled)} bytes, "
              f"skew={float(d.max_received)/max(float(d.mean_received),1e-9):.2f}, "
              f"overflow={int(d.send_overflow)}")

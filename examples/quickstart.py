"""Quickstart: the EE-Join operator end to end in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a synthetic corpus with planted noisy mentions, gathers data
statistics, lets the cost model choose an execution plan (paper §4-§5),
executes it, and checks the result against the exact oracle.
"""
import numpy as np

from repro.core.cost_model import CostParams
from repro.core.eejoin import EEJoinConfig, EEJoinOperator
from repro.data.synth import make_corpus
from repro.extraction.oracle import oracle_extract

GAMMA = 0.8

# 1. a corpus with planted, noisy mentions of a 64-entity dictionary
corpus = make_corpus(
    num_docs=32, doc_len=128, vocab_size=2048, num_entities=64,
    mention_dist="zipf", mentions_per_doc=4.0, seed=7,
)
print(f"corpus: {corpus.doc_tokens.shape} docs, "
      f"{corpus.dictionary.num_entities} entities, "
      f"{len(corpus.planted)} planted mentions")

# 2. the operator: statistics -> cost-based plan -> prepare -> execute
op = EEJoinOperator(corpus.dictionary, EEJoinConfig(gamma=GAMMA))
stats = op.gather_statistics(corpus.doc_tokens[:16],
                             total_docs=len(corpus.doc_tokens))
plan = op.choose_plan(stats, CostParams(num_devices=1))
print(f"chosen plan: head={plan.head.algo}:{plan.head.scheme} "
      f"tail={plan.tail.algo}:{plan.tail.scheme} split={plan.split} "
      f"(predicted {plan.predicted_cost:.2e}s, "
      f"{plan.evaluations} cost evaluations)")

prepared = op.prepare(plan)
matches = op.execute(prepared, corpus.doc_tokens)

# 3. compare against the exact oracle for each side's semantics
t_extra = oracle_extract(corpus.doc_tokens, corpus.dictionary, GAMMA, "extra")
t_var = oracle_extract(corpus.doc_tokens, corpus.dictionary, GAMMA,
                       "variant_exact")
truth = set()
for side, a, b in ((plan.head, 0, plan.split),
                   (plan.tail, plan.split, corpus.dictionary.num_entities)):
    t = t_var if side.scheme == "variant" else t_extra
    truth |= {x for x in t if a <= x[3] < b}
got = matches.to_set()
print(f"matches: {len(got)} found; "
      f"recall={len(got & truth) / max(len(truth), 1):.3f} "
      f"precision={len(got & truth) / max(len(got), 1):.3f} vs oracle")

d, p, ln, e = next(iter(sorted(got)))
print(f"example: doc {d} pos {p} len {ln} -> entity {e} "
      f"{corpus.dictionary.tokens[e, :corpus.dictionary.lengths[e]].tolist()}")
